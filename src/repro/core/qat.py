"""EC4T — entropy-constrained 4-bit training (paper §IV), as a parameterisation.

A quantized tensor is stored in the trainable tree as a dict

    {"w": master_fp_weights, "omega": (4,) basis centroids}

with a mirrored non-trainable quantization state

    {"probs": (16,) EMA cluster probabilities}

The forward pass uses :func:`fake_quant`:

    codes = stop_grad( ECL_assign(w, omega, probs, lam) )      # §IV-C
    w_hat = Σ_i ω_i · bit_i(codes)       (differentiable in ω) # eq. (1)
    w_used = w_hat + (w - stop_grad(w))                        # STE, §IV-D

Reverse-mode AD then yields exactly the paper's two update rules at once:
  * ∂L/∂w      = δW               (straight-through to the masters)
  * ∂L/∂ω_i    = Σ_j δW_j B_i[j]  (centroid fine-tuning, eq. (2))

The probability state is EMA-updated from fresh assignments once per step
(one alternating ECL iteration per training step — see ``ecl.py``).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import bitplanes, ecl

QUANT_KEYS = frozenset({"w", "omega"})
FROZEN_KEYS = frozenset({"packed", "omega"})


def is_quant_leaf(node: Any) -> bool:
    """A dict holding a quantized tensor parameterisation."""
    return isinstance(node, dict) and QUANT_KEYS.issubset(node.keys())


def is_frozen_leaf(node: Any) -> bool:
    """A dict holding a frozen (packed 4-bit) serving tensor."""
    return isinstance(node, dict) and FROZEN_KEYS.issubset(node.keys()) \
        and "w" not in node


def make_quant_param(w: jax.Array) -> dict:
    return {"w": w, "omega": bitplanes.init_omega_from_weights(w)}


def init_qstate_leaf(lead: tuple = ()) -> dict:
    return {"probs": jnp.full((*lead, ecl.NUM_CODES),
                              1.0 / ecl.NUM_CODES, jnp.float32)}


def fake_quant(w: jax.Array, omega: jax.Array, probs: jax.Array,
               lam, dtype=None) -> jax.Array:
    """STE fake-quantization with differentiable centroid path."""
    dtype = dtype or w.dtype
    codes = jax.lax.stop_gradient(ecl.assign(w, omega, probs, lam))
    w_hat = bitplanes.decode(codes, omega, jnp.float32)
    ste = w.astype(jnp.float32) - jax.lax.stop_gradient(w.astype(jnp.float32))
    return (w_hat + ste).astype(dtype)


def apply_quant(node: dict, qstate: dict, lam, dtype=None) -> jax.Array:
    return fake_quant(node["w"], node["omega"], qstate["probs"], lam, dtype)


# --------------------------------------------------------------- tree utils

def _map_quant_leaves(fn: Callable, tree: Any, *rest: Any) -> Any:
    """Map ``fn`` over quantized-parameter dicts (treated as leaves)."""
    return jax.tree_util.tree_map(
        fn, tree, *rest, is_leaf=is_quant_leaf)


def build_qstate(params: Any) -> Any:
    """Mirror tree with a probs state per quantized leaf.

    Non-quantized leaves mirror to a tiny uint8 placeholder sharing the
    leaf's *leading* dim — a leaf (not None) at every position keeps the
    tree tree_map-compatible with the parameter tree, and the leading dim
    keeps layer-stacked mirrors sliceable by the scan-over-layers.
    """
    def f(node):
        if is_quant_leaf(node):
            return init_qstate_leaf(node["w"].shape[:-2])
        if hasattr(node, "ndim") and node.ndim >= 1:
            return jnp.zeros(node.shape[:1], jnp.uint8)
        return jnp.zeros((), jnp.uint8)
    return jax.tree_util.tree_map(f, params, is_leaf=is_quant_leaf)


def update_qstate(params: Any, qstate: Any, lam,
                  momentum: float = 0.9) -> Any:
    """One EMA step of the per-tensor cluster probabilities (ECL iteration).

    Runs under jit/pjit; the histogram reduction over a sharded master weight
    produces a single 16-element psum per tensor.
    """
    def f(node, qs):
        if not is_quant_leaf(node):
            return qs
        codes = ecl.assign(node["w"], node["omega"], qs["probs"], lam)
        return {"probs": ecl.update_probs(qs["probs"], codes, momentum)}
    return jax.tree_util.tree_map(f, params, qstate, is_leaf=is_quant_leaf)


def quantize_tree(params: Any, qstate: Any, lam) -> Any:
    """Freeze: replace each quantized leaf with {codes, omega} (inference)."""
    def f(node, qs):
        if not is_quant_leaf(node):
            return node
        codes = ecl.assign(node["w"], node["omega"], qs["probs"], lam)
        return {"codes": codes, "omega": node["omega"]}
    return jax.tree_util.tree_map(f, params, qstate, is_leaf=is_quant_leaf)


def freeze_tree(params: Any, qstate: Any, lam) -> Any:
    """Serving form: every quantized leaf becomes {"packed", "omega"} with
    row-pair-packed uint8 codes — 4 bits/weight in HBM (the paper's traffic
    win; the dry-run's memory roofline term sees exactly these bytes).
    Requires even contraction dims (all assigned archs satisfy this)."""
    def f(node, qs):
        if not is_quant_leaf(node):
            return node
        codes = ecl.assign(node["w"], node["omega"], qs["probs"], lam)
        return {"packed": bitplanes.pack_codes_rows(codes),
                "omega": node["omega"].astype(jnp.float32)}
    return jax.tree_util.tree_map(f, params, qstate, is_leaf=is_quant_leaf)


def decode_frozen(node: dict, dtype=jnp.float32) -> jax.Array:
    codes = bitplanes.unpack_codes_rows(node["packed"])
    return bitplanes.decode(codes, node["omega"], dtype)


def stats(params: Any, qstate: Any, lam) -> dict:
    """Global sparsity / entropy / size diagnostics over quantized leaves."""
    total, zeros, bits = [], [], []

    def f(node, qs):
        if is_quant_leaf(node):
            codes = ecl.assign(node["w"], node["omega"], qs["probs"], lam)
            lead_nd = node["omega"].ndim - 1
            per_lead = ecl.entropy_bits(ecl.histogram(codes, lead_nd))
            elems_per_lead = codes.shape[-2] * codes.shape[-1] \
                if codes.ndim >= 2 else codes.size
            total.append(jnp.asarray(codes.size, jnp.float32))
            zeros.append(jnp.sum((codes == 0).astype(jnp.float32)))
            bits.append(jnp.sum(per_lead) * elems_per_lead)
        return node

    _map_quant_leaves(f, params, qstate)
    n = sum(total) if total else jnp.asarray(1.0)
    return {
        "quant_params": n,
        "sparsity": (sum(zeros) / n) if zeros else jnp.asarray(0.0),
        "entropy_bits_per_weight": (sum(bits) / n) if bits else jnp.asarray(0.0),
    }
