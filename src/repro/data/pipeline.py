"""Host→device input pipeline: sharded placement, prefetch, skip-ahead.

``ShardedFeed`` turns the host-side numpy generators (data/synthetic.py)
into device arrays laid out for the mesh (batch over the data axes) with a
background prefetch thread of bounded depth — the straggler-mitigation
posture from DESIGN.md §4: the host never blocks the step on I/O, and a
slow host only ever delays its *own* shard by up to ``depth`` steps.

On a real multi-host pod each process would call
``jax.make_array_from_process_local_data`` with its local slice; in this
single-process container ``jax.device_put`` with a NamedSharding performs
the same logical placement (the sharding layout is identical, which is what
the dry-run validates).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def batch_sharding(mesh: jax.sharding.Mesh, ndim: int,
                   data_axes=("pod", "data")) -> NamedSharding:
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    return NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))


def place(batch: dict, mesh: Optional[jax.sharding.Mesh]) -> dict:
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, batch_sharding(mesh, np.ndim(v)))
            for k, v in batch.items()}


class ShardedFeed:
    """Prefetching iterator over step-seeded batches.

    ``batch_fn(step) -> dict of numpy``; restart = construct with
    ``start_step`` from the checkpoint (exact skip-ahead, no replay)."""

    def __init__(self, batch_fn: Callable[[int], dict],
                 mesh: Optional[jax.sharding.Mesh] = None,
                 start_step: int = 0, depth: int = 2):
        self._fn = batch_fn
        self._mesh = mesh
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fn(step)
            try:
                self._q.put((step, batch), timeout=0.5)
            except queue.Full:
                continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        while True:
            try:
                step, batch = self._q.get(timeout=0.5)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration
                continue
            if step < self._step:      # stale after a skip-ahead
                continue
            self._step = step + 1
            return place(batch, self._mesh)

    def close(self):
        self._stop.set()
