"""Deterministic synthetic data — seeded per (task, step, host).

Every batch is a pure function of (seed, step), so fault-tolerant restart
needs no data-state checkpoint beyond the step counter: skip-ahead is free
and exact (runtime/fault.py relies on this).  Two generators:

* LM token streams with a Zipf-ish marginal and short-range structure
  (next-token = f(prev) + noise) so cross-entropy demonstrably drops during
  the example runs — pure-uniform tokens would make loss curves flat.
* GSC/HR-like feature-vector classification sets for the paper's MLPs,
  with class-conditional Gaussian clusters (linearly separable at a margin,
  so small MLPs reach high accuracy quickly, mirroring the paper's tasks).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataCfg:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def lm_batch(cfg: LMDataCfg, step: int) -> dict:
    """(tokens, labels) uint/int32 arrays for one step (host-side numpy)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    # structured stream: x_{t+1} = (a * x_t + c + noise) mod v
    a = 31337 % v or 1
    x0 = rng.integers(0, v, size=(b, 1))
    noise = rng.integers(0, max(v // 64, 2), size=(b, s))
    toks = np.empty((b, s + 1), np.int64)
    toks[:, :1] = x0
    for t in range(s):
        toks[:, t + 1] = (a * toks[:, t] + 7 + noise[:, t % s]) % v
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def lm_batches(cfg: LMDataCfg, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


@dataclasses.dataclass(frozen=True)
class ClsDataCfg:
    d_in: int
    n_classes: int
    batch: int
    margin: float = 2.0
    seed: int = 0


def _class_means(cfg: ClsDataCfg) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xC1A55]))
    m = rng.normal(size=(cfg.n_classes, cfg.d_in))
    return cfg.margin * m / np.linalg.norm(m, axis=1, keepdims=True)


def cls_batch(cfg: ClsDataCfg, step: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 1, step]))
    labels = rng.integers(0, cfg.n_classes, size=(cfg.batch,))
    x = _class_means(cfg)[labels] + rng.normal(size=(cfg.batch, cfg.d_in))
    return {"x": x.astype(np.float32), "labels": labels.astype(np.int32)}


def cls_batches(cfg: ClsDataCfg, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield cls_batch(cfg, step)
        step += 1
