"""Adam (paper §IV-E: centroids and masters are updated with Adam).

Hand-rolled (no optax in this environment), pytree-generic: quantized
leaves ({"w", "omega"}) are ordinary subtrees here — the same Adam moments
cover masters and the 4 basis centroids, which is exactly the paper's
update rule once the gradients have been produced by the differentiable
decode (core/qat.py).

State layout mirrors the parameter tree (m, v per leaf) so the ZeRO-1
partition rules in runtime/sharding.py can map 1:1 over it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0


def init(params: Any) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(params: Any, grads: Any, state: dict, cfg: AdamConfig,
          lr_scale: jax.Array | float = 1.0):
    """One Adam step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
