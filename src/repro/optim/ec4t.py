"""EC4T training step assembly (paper §IV, full loop).

One training step =
  1. fake-quant forward + backward (STE; gradients w.r.t. masters *and* the
     4 basis centroids fall out of the differentiable decode — eq. (2)),
  2. Adam on the whole tree (masters + ω + everything unquantized),
  3. one alternating-ECL iteration: EMA-update the per-tensor cluster
     probabilities from fresh assignments (core/qat.update_qstate),
  4. (MoE archs) deepseek-style aux-free balancing: nudge the router's
     bias-correction toward the under-loaded experts.

All of it runs inside one jit/pjit; the probs update over a sharded master
weight reduces to a 16-wide psum per tensor (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core import qat
from . import adam
from .grad_compress import GradCompressCfg, compress_grads, init_error_state


def make_train_step(loss_fn: Callable, adam_cfg: adam.AdamConfig, *,
                    lam: float | Callable = 0.02,
                    probs_momentum: float = 0.9,
                    lr_schedule: Optional[Callable] = None,
                    compress: Optional[GradCompressCfg] = None,
                    mesh=None):
    """Build the jittable EC4T train step.

    loss_fn(params, qstate, batch, lam) -> (loss, metrics).
    Returns step(state, batch) -> (state, metrics) with
    state = {params, opt, qstate, err?}.
    """

    def step(state, batch):
        p, opt, qs = state["params"], state["opt"], state["qstate"]
        lam_t = lam(opt["step"]) if callable(lam) else lam
        lr_scale = lr_schedule(opt["step"]) if lr_schedule else 1.0

        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, qs, batch, lam_t), has_aux=True)(p)

        err = state.get("err")
        if compress is not None and err is not None:
            grads, err = compress_grads(grads, err, compress, mesh=mesh)

        new_p, new_opt, opt_metrics = adam.apply(p, grads, opt, adam_cfg,
                                                 lr_scale=lr_scale)
        new_qs = qat.update_qstate(new_p, qs, lam_t, probs_momentum)

        metrics = dict(metrics, **opt_metrics, lam=jnp.asarray(lam_t),
                       lr_scale=jnp.asarray(lr_scale))
        new_state = {"params": new_p, "opt": new_opt, "qstate": new_qs}
        if err is not None:
            new_state["err"] = err
        return new_state, metrics

    return step


def init_train_state(params: Any,
                     compress: Optional[GradCompressCfg] = None) -> dict:
    state = {"params": params, "opt": adam.init(params),
             "qstate": qat.build_qstate(params)}
    if compress is not None:
        state["err"] = init_error_state(params, compress)
    return state


def update_moe_bias(params: Any, load_frac: jax.Array, *,
                    gamma: float = 1e-3) -> Any:
    """deepseek-v3 aux-loss-free balancing: decrease the routing bias of
    overloaded experts, increase underloaded (sign update, rate γ).
    ``load_frac``: (E,) fraction of assignments per expert this step."""
    def f(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if name.endswith("router/bias_correction"):
            target = 1.0 / leaf.shape[-1]
            return leaf + gamma * jnp.sign(target - load_frac)
        return leaf
    return jax.tree_util.tree_map_with_path(f, params)
