"""int8 error-feedback gradient compression for the DP all-reduce.

Beyond-paper optimization in the paper's own spirit (shrink the bytes that
move): the data-parallel gradient all-reduce is executed over int8-quantized
gradients inside a ``shard_map`` psum, cutting DP collective bytes 4× vs
f32 / 2× vs bf16.  The quantization residual is carried in an
error-feedback buffer (1-bit-Adam-style), which keeps SGD/Adam convergence
unaffected to first order — ``tests/test_optim.py`` checks the compressed
path tracks the exact path.

Only tensors above ``min_size`` participate (tiny tensors: rounding error
isn't worth it, and ω/centroids/norms stay exact — the paper's sensitive
parameters keep full precision everywhere, including in their gradients).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map


@dataclasses.dataclass(frozen=True)
class GradCompressCfg:
    min_size: int = 65536          # don't compress below this many elements
    data_axes: Tuple[str, ...] = ("data",)


def _eligible(leaf: jax.Array, cfg: GradCompressCfg) -> bool:
    return leaf.size >= cfg.min_size and jnp.issubdtype(
        leaf.dtype, jnp.floating)


def init_error_state(params: Any, cfg: GradCompressCfg) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32)
        if _eligible(p, cfg) else jnp.zeros((), jnp.float32), params)


def _quantize(g: jax.Array):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, err: Any, cfg: GradCompressCfg, *,
                   mesh: Optional[jax.sharding.Mesh] = None):
    """Quantize (grad + error) to int8, average, update error feedback.

    Without a mesh (single-process tests) the roundtrip is local — the same
    numerics, no collective.  With a mesh, the int8 psum runs inside
    shard_map over the data axes so the wire format really is int8.
    """
    def one(g, e):
        if e.ndim == 0:            # ineligible leaf: exact
            return g, e
        gf = g.astype(jnp.float32) + e

        if mesh is not None:
            axes = tuple(a for a in cfg.data_axes if a in mesh.axis_names)
            n_dev = 1
            for a in axes:
                n_dev *= mesh.shape[a]
            if n_dev > 1:
                def allreduce_q(x):
                    q, s = _quantize(x)
                    qsum = jax.lax.psum(q.astype(jnp.int32), axes)
                    return qsum.astype(jnp.float32) * s / n_dev, q, s
                # grads enter replicated over data axes (pjit already
                # reduced them); production wiring would psum here instead.
                deq, q, s = shard_map(
                    allreduce_q, mesh=mesh,
                    in_specs=P(*[None] * gf.ndim),
                    out_specs=(P(*[None] * gf.ndim),
                               P(*[None] * gf.ndim), P()),
                )(gf)
                new_e = gf - q.astype(jnp.float32) * s
                return deq.astype(g.dtype), new_e

        q, s = _quantize(gf)
        deq = q.astype(jnp.float32) * s
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
