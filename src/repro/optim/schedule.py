"""LR schedules (warmup + cosine decay) and λ (entropy-penalty) ramps.

The paper trains at a fixed regularisation strength per run (Table II shows
two operating points); ramping λ from 0 lets a single run anneal into the
low-entropy regime without an early accuracy cliff — the standard practice
this framework defaults to.
"""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)


def lambda_ramp(step, *, lam: float, ramp_steps: int):
    """Linear 0 -> λ ramp over ramp_steps."""
    s = jnp.asarray(step, jnp.float32)
    return lam * jnp.clip(s / jnp.maximum(ramp_steps, 1), 0.0, 1.0)
